"""Two-stage reduction vs a dense numpy oracle (paper Eq. 1/6/7/8)."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.reduction import (
    composite_key_fits_int32,
    two_stage_reduce,
)


def _oracle(doc_ids, qtok_ids, scores, valid, mse, n_docs, q_max):
    """Dense score matrix + row-sum with imputation: the textbook Eq. (1)."""
    mat = np.full((n_docs, q_max), -np.inf)
    seen = np.zeros((n_docs,), bool)
    for d, t, s, v in zip(doc_ids, qtok_ids, scores, valid):
        if v:
            mat[d, t] = max(mat[d, t], s)
            seen[d] = True
    out = {}
    for d in range(n_docs):
        if not seen[d]:
            continue
        total = 0.0
        for t in range(q_max):
            total += mat[d, t] if np.isfinite(mat[d, t]) else mse[t]
        out[d] = total
    return out


@settings(max_examples=40, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    n=st.integers(4, 200),
    n_docs=st.integers(1, 30),
    q_max=st.integers(1, 8),
    k=st.integers(1, 4),
)
def test_two_stage_reduce_matches_oracle(seed, n, n_docs, q_max, k):
    if k > n:
        k = n
    rng = np.random.default_rng(seed)
    doc_ids = rng.integers(0, n_docs, n).astype(np.int32)
    qtok_ids = rng.integers(0, q_max, n).astype(np.int32)
    scores = rng.standard_normal(n).astype(np.float32)
    valid = rng.random(n) > 0.2
    mse = (rng.standard_normal(q_max) * 0.1).astype(np.float32)

    res = two_stage_reduce(
        jnp.asarray(doc_ids),
        jnp.asarray(qtok_ids),
        jnp.asarray(scores),
        jnp.asarray(valid),
        jnp.asarray(mse),
        q_max=q_max,
        k=k,
    )
    got_scores = np.asarray(res.scores)
    got_docs = np.asarray(res.doc_ids)

    want = _oracle(doc_ids, qtok_ids, scores, valid, mse, n_docs, q_max)
    want_sorted = sorted(want.items(), key=lambda kv: -kv[1])

    n_expect = min(k, len(want))
    for i in range(n_expect):
        assert np.isfinite(got_scores[i])
        assert got_docs[i] in want, got_docs[i]
        np.testing.assert_allclose(got_scores[i], want[got_docs[i]], rtol=1e-4, atol=1e-4)
        # i-th returned score matches the i-th best oracle score.
        np.testing.assert_allclose(
            got_scores[i], want_sorted[i][1], rtol=1e-4, atol=1e-4
        )
    # Padding beyond the unique-doc count.
    for i in range(n_expect, k):
        assert got_docs[i] == -1 and got_scores[i] == -np.inf


def test_all_invalid_returns_padding():
    res = two_stage_reduce(
        jnp.zeros(8, jnp.int32),
        jnp.zeros(8, jnp.int32),
        jnp.zeros(8, jnp.float32),
        jnp.zeros(8, bool),
        jnp.zeros(4, jnp.float32),
        q_max=4,
        k=3,
    )
    assert np.all(np.asarray(res.doc_ids) == -1)
    assert np.all(np.asarray(res.scores) == -np.inf)


def test_missing_entries_imputed():
    """One doc retrieved for qtok 0 only; other qtok contributes m."""
    mse = jnp.asarray([0.0, 0.25])
    res = two_stage_reduce(
        jnp.asarray([7], jnp.int32),
        jnp.asarray([0], jnp.int32),
        jnp.asarray([0.5], jnp.float32),
        jnp.asarray([True]),
        mse,
        q_max=2,
        k=1,
    )
    np.testing.assert_allclose(float(res.scores[0]), 0.5 + 0.25, rtol=1e-6)
    assert int(res.doc_ids[0]) == 7


def test_composite_key_overflow_detection():
    assert composite_key_fits_int32(n_docs=1000, q_max=32)
    assert not composite_key_fits_int32(n_docs=2**30, q_max=4)
    # Boundary: largest composite must stay strictly below the sentinel.
    assert not composite_key_fits_int32(n_docs=(2**31 - 1) // 8 + 1, q_max=8)


def test_wide_key_fallback_matches_oracle():
    """Regression: doc_id * q_max + qtok overflows int32 -> the checked
    n_docs path must switch to the two-key sort and stay correct."""
    q_max, k = 4, 3
    n_docs = 2**30 + 7  # n_docs * q_max is far beyond int32
    assert not composite_key_fits_int32(n_docs, q_max)
    doc_ids = np.array(
        [2**30 + 5, 2**30 + 5, 3, 2**29, 2**30 + 5, 3, 2**29, 9], np.int32
    )
    qtok_ids = np.array([0, 0, 1, 3, 2, 1, 0, 3], np.int32)
    scores = np.array([0.5, 0.9, 0.3, 0.7, 0.2, 0.8, 0.1, 0.4], np.float32)
    valid = np.array([1, 1, 1, 1, 1, 0, 1, 1], bool)
    mse = np.array([0.01, 0.02, 0.03, 0.04], np.float32)

    res = two_stage_reduce(
        jnp.asarray(doc_ids), jnp.asarray(qtok_ids), jnp.asarray(scores),
        jnp.asarray(valid), jnp.asarray(mse),
        q_max=q_max, k=k, n_docs=n_docs,
    )
    # Sparse oracle (the dense _oracle cannot allocate 2^30 rows).
    best: dict = {}
    for d, t, s, vv in zip(doc_ids, qtok_ids, scores, valid):
        if vv:
            best[(int(d), int(t))] = max(best.get((int(d), int(t)), -np.inf), s)
    want = {}
    for d in {int(d) for d, v in zip(doc_ids, valid) if v}:
        want[d] = sum(
            best.get((d, t), float(mse[t])) for t in range(q_max)
        )
    want_sorted = sorted(want.items(), key=lambda kv: -kv[1])
    for i in range(min(k, len(want_sorted))):
        assert int(res.doc_ids[i]) == want_sorted[i][0]
        np.testing.assert_allclose(
            float(res.scores[i]), want_sorted[i][1], rtol=1e-5, atol=1e-5
        )


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    n=st.integers(4, 120),
    n_docs=st.integers(1, 30),
    q_max=st.integers(1, 8),
)
def test_wide_key_path_matches_fast_path(seed, n, n_docs, q_max):
    """Force the two-key sort on small inputs (fake huge n_docs) and check
    bit-identical results against the int32 composite path."""
    rng = np.random.default_rng(seed)
    doc_ids = rng.integers(0, n_docs, n).astype(np.int32)
    qtok_ids = rng.integers(0, q_max, n).astype(np.int32)
    scores = rng.standard_normal(n).astype(np.float32)
    valid = rng.random(n) > 0.2
    mse = (rng.standard_normal(q_max) * 0.1).astype(np.float32)
    args = (
        jnp.asarray(doc_ids), jnp.asarray(qtok_ids), jnp.asarray(scores),
        jnp.asarray(valid), jnp.asarray(mse),
    )
    a = two_stage_reduce(*args, q_max=q_max, k=4, n_docs=n_docs)
    b = two_stage_reduce(*args, q_max=q_max, k=4, n_docs=2**31 - 1)
    np.testing.assert_allclose(
        np.asarray(a.scores), np.asarray(b.scores), rtol=1e-5, atol=1e-5
    )
    np.testing.assert_array_equal(np.asarray(a.doc_ids), np.asarray(b.doc_ids))


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    n=st.integers(4, 300),
    n_docs=st.integers(1, 10),
    q_max=st.integers(1, 6),
    wide=st.booleans(),
)
def test_segment_impl_parity_duplicate_heavy(seed, n, n_docs, q_max, wide):
    """Ragged/duplicate-heavy parity between the two reduction impls,
    covering BOTH sort paths: the int32 composite key and the wide two-key
    lexicographic sort (``wide`` fakes a huge n_docs so the composite would
    overflow — previously the segment impl had no parity test there).

    Few docs + few qtokens over many entries maximizes duplicate
    (doc, qtok) runs — exactly what a ragged worklist produces when one
    document's tokens span several probed clusters. Top-k doc ids must be
    bit-identical; scores may differ by summation order only
    (``segment_sum`` scatter-adds in index order, ``associative_scan``
    combines as a tree), so a few float32 ulps.
    """
    rng = np.random.default_rng(seed)
    doc_ids = rng.integers(0, n_docs, n).astype(np.int32)
    qtok_ids = rng.integers(0, q_max, n).astype(np.int32)
    scores = rng.standard_normal(n).astype(np.float32)
    # Duplicate-heavy score ties too: quantize a third of the entries.
    ties = rng.random(n) < 0.33
    scores[ties] = np.round(scores[ties], 1)
    valid = rng.random(n) > 0.3
    mse = (rng.standard_normal(q_max) * 0.1).astype(np.float32)
    nd = (2**31 - 1) if wide else n_docs
    if wide:
        assert not composite_key_fits_int32(nd, q_max)
    args = (
        jnp.asarray(doc_ids), jnp.asarray(qtok_ids), jnp.asarray(scores),
        jnp.asarray(valid), jnp.asarray(mse),
    )
    a = two_stage_reduce(*args, q_max=q_max, k=4, impl="scan", n_docs=nd)
    b = two_stage_reduce(*args, q_max=q_max, k=4, impl="segment", n_docs=nd)
    np.testing.assert_array_equal(np.asarray(a.doc_ids), np.asarray(b.doc_ids))
    np.testing.assert_allclose(
        np.asarray(a.scores), np.asarray(b.scores), rtol=0, atol=4e-6
    )


def test_pad_to_k_pads_short_candidate_streams():
    """Flat-path contract: a statically short stream (ragged worklist bound
    < k) pads with invalid entries instead of raising."""
    args = (
        jnp.asarray([3, 3, 5], jnp.int32),
        jnp.asarray([0, 1, 0], jnp.int32),
        jnp.asarray([0.5, 0.25, 0.1], jnp.float32),
        jnp.asarray([True, True, True]),
        jnp.zeros(2, jnp.float32),
    )
    with np.testing.assert_raises(ValueError):
        two_stage_reduce(*args, q_max=2, k=5)
    res = two_stage_reduce(*args, q_max=2, k=5, pad_to_k=True)
    assert int(res.doc_ids[0]) == 3
    np.testing.assert_allclose(float(res.scores[0]), 0.75, rtol=1e-6)
    assert np.all(np.asarray(res.doc_ids[2:]) == -1)
    assert np.all(np.asarray(res.scores[2:]) == -np.inf)


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    n=st.integers(4, 200),
    n_docs=st.integers(1, 30),
    q_max=st.integers(1, 8),
)
def test_segment_impl_matches_scan_impl(seed, n, n_docs, q_max):
    """§Perf variant ("segment") must be bit-compatible with baseline."""
    rng = np.random.default_rng(seed)
    doc_ids = rng.integers(0, n_docs, n).astype(np.int32)
    qtok_ids = rng.integers(0, q_max, n).astype(np.int32)
    scores = rng.standard_normal(n).astype(np.float32)
    valid = rng.random(n) > 0.2
    mse = (rng.standard_normal(q_max) * 0.1).astype(np.float32)
    args = (
        jnp.asarray(doc_ids), jnp.asarray(qtok_ids), jnp.asarray(scores),
        jnp.asarray(valid), jnp.asarray(mse),
    )
    a = two_stage_reduce(*args, q_max=q_max, k=4, impl="scan")
    b = two_stage_reduce(*args, q_max=q_max, k=4, impl="segment")
    np.testing.assert_allclose(np.asarray(a.scores), np.asarray(b.scores), rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(a.doc_ids), np.asarray(b.doc_ids))
