"""Unified ``Retriever`` API: parity vs the legacy entry points (local,
batched, sharded; fused vs materialize), plan validation, and the
deprecated-flag shims."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    IndexBuildConfig,
    Retriever,
    WarpSearchConfig,
    build_index,
    build_sharded_index,
    search,
    search_batch,
    sharded_search,
)
from repro.data import make_corpus, make_queries


@pytest.fixture(scope="module")
def setup():
    corpus = make_corpus(n_docs=250, mean_doc_len=14, seed=21)
    idx = build_index(
        corpus.emb, corpus.token_doc_ids, corpus.n_docs,
        IndexBuildConfig(n_centroids=64, nbits=4, kmeans_iters=3),
    )
    q, qmask, rel = make_queries(corpus, n_queries=6, seed=22)
    return corpus, idx, q, qmask, rel


CFGS = [
    WarpSearchConfig(nprobe=8, k=10, t_prime=600),
    WarpSearchConfig(nprobe=8, k=10, t_prime=600, gather="fused"),
    WarpSearchConfig(nprobe=8, k=10, t_prime=600, gather="fused",
                     memory="scan_qtokens"),
    WarpSearchConfig(nprobe=8, k=10, t_prime=600, executor="kernel"),
]


@pytest.mark.parametrize("cfg", CFGS, ids=lambda c: f"{c.gather}/{c.executor}/{c.memory}")
def test_retriever_matches_legacy_search(setup, cfg):
    _, idx, q, qmask, _ = setup
    r = Retriever.from_index(idx)
    plan = r.plan(cfg)
    for i in range(3):
        a = plan.retrieve(q[i], qmask[i])
        b = search(idx, q[i], jnp.asarray(qmask[i]), cfg)
        np.testing.assert_allclose(
            np.asarray(a.scores), np.asarray(b.scores), rtol=1e-5, atol=1e-5
        )
        np.testing.assert_array_equal(np.asarray(a.doc_ids), np.asarray(b.doc_ids))


def test_retriever_batch_matches_legacy(setup):
    _, idx, q, qmask, _ = setup
    cfg = WarpSearchConfig(nprobe=8, k=10, t_prime=600)
    r = Retriever.from_index(idx)
    a = r.retrieve_batch(q[:4], qmask[:4], config=cfg)
    b = search_batch(idx, q[:4], jnp.asarray(qmask[:4]), cfg)
    np.testing.assert_allclose(
        np.asarray(a.scores), np.asarray(b.scores), rtol=1e-5, atol=1e-5
    )
    np.testing.assert_array_equal(np.asarray(a.doc_ids), np.asarray(b.doc_ids))


def test_fused_matches_materialize_via_plans(setup):
    _, idx, q, qmask, _ = setup
    r = Retriever.from_index(idx)
    base = r.plan(WarpSearchConfig(nprobe=8, k=10, t_prime=600))
    fused = r.plan(WarpSearchConfig(nprobe=8, k=10, t_prime=600, gather="fused"))
    for i in range(3):
        a = base.retrieve(q[i], qmask[i])
        b = fused.retrieve(q[i], qmask[i])
        np.testing.assert_allclose(
            np.asarray(a.scores), np.asarray(b.scores), rtol=1e-4, atol=1e-4
        )
        np.testing.assert_array_equal(np.asarray(a.doc_ids), np.asarray(b.doc_ids))


def test_retriever_sharded_matches_legacy(setup):
    corpus, _, q, qmask, _ = setup
    sidx = build_sharded_index(
        corpus.emb, corpus.token_doc_ids, corpus.n_docs,
        n_shards=len(jax.devices()),
        config=IndexBuildConfig(n_centroids=32, nbits=4, kmeans_iters=2),
    )
    cfg = WarpSearchConfig(nprobe=8, k=10, t_prime=600)
    r = Retriever.from_index(sidx)
    plan = r.plan(cfg)
    for i in range(3):
        a = plan.retrieve(q[i], qmask[i])
        b = sharded_search(sidx, q[i], jnp.asarray(qmask[i]), cfg)
        np.testing.assert_allclose(
            np.asarray(a.scores), np.asarray(b.scores), rtol=1e-5, atol=1e-5
        )
        np.testing.assert_array_equal(np.asarray(a.doc_ids), np.asarray(b.doc_ids))
    # Sharded batch goes through the same plan (query_batch shard_map body).
    ab = plan.retrieve_batch(q[:2], qmask[:2])
    for i in range(2):
        a1 = plan.retrieve(q[i], qmask[i])
        np.testing.assert_array_equal(
            np.asarray(ab.doc_ids[i]), np.asarray(a1.doc_ids)
        )


def test_build_constructor_local_and_sharded(setup):
    corpus, _, q, qmask, rel = setup
    r_local = Retriever.build(
        corpus.emb, corpus.token_doc_ids, corpus.n_docs,
        IndexBuildConfig(n_centroids=32, nbits=4, kmeans_iters=2),
    )
    assert not r_local.is_sharded and r_local.n_shards == 1
    r_shard = Retriever.build(
        corpus.emb, corpus.token_doc_ids, corpus.n_docs,
        IndexBuildConfig(n_centroids=32, nbits=4, kmeans_iters=2),
        n_shards=len(jax.devices()),
    )
    assert r_shard.is_sharded
    res = r_shard.retrieve(q[0], qmask[0], config=WarpSearchConfig(nprobe=8, k=10))
    assert np.asarray(res.doc_ids).shape == (10,)


def test_sharded_t_prime_resolves_from_true_token_count(setup):
    """Padding tokens must not inflate the imputation threshold."""
    corpus, *_ = setup
    sidx = build_sharded_index(
        corpus.emb, corpus.token_doc_ids, corpus.n_docs,
        n_shards=len(jax.devices()),
        config=IndexBuildConfig(n_centroids=32, nbits=4, kmeans_iters=2),
    )
    assert sidx.n_tokens_total == corpus.n_tokens
    assert sidx.resolved_n_tokens() == corpus.n_tokens
    plan = Retriever.from_index(sidx).plan(WarpSearchConfig(nprobe=8, k=10))
    want = WarpSearchConfig(nprobe=8, k=10).resolved_t_prime(corpus.n_tokens)
    assert plan.t_prime == want
    # The old bug resolved from n_tokens_padded * n_shards, which over-counts
    # whenever shards are padded to a common geometry.
    assert sidx.n_tokens_padded * sidx.n_shards >= corpus.n_tokens


# ---- plan validation ----

def test_plan_rejects_bad_strategy_strings():
    with pytest.raises(ValueError, match="gather"):
        WarpSearchConfig(gather="fussed")
    with pytest.raises(ValueError, match="executor"):
        WarpSearchConfig(executor="gpu")
    with pytest.raises(ValueError, match="memory"):
        WarpSearchConfig(memory="tiny")
    with pytest.raises(ValueError, match="reduce_impl"):
        WarpSearchConfig(reduce_impl="tree")
    with pytest.raises(ValueError, match="sum_impl"):
        WarpSearchConfig(sum_impl="simd")


def test_plan_rejects_bad_geometry(setup):
    _, idx, *_ = setup  # 64 centroids
    r = Retriever.from_index(idx)
    with pytest.raises(ValueError, match="nprobe"):
        r.plan(WarpSearchConfig(nprobe=65, k=10))
    # k_impute is clamped (not rejected) to the centroid count at plan time.
    assert r.plan(WarpSearchConfig(nprobe=8, k=10, k_impute=100000)).k_impute == 64
    with pytest.raises(ValueError, match="k="):
        r.plan(WarpSearchConfig(nprobe=1, k=10 ** 9))
    with pytest.raises(ValueError, match="nprobe"):
        r.plan(WarpSearchConfig(nprobe=0, k=10))


def test_plan_is_cached_and_resolved(setup):
    _, idx, *_ = setup
    r = Retriever.from_index(idx)
    cfg = WarpSearchConfig(nprobe=8, k=10)
    p1, p2 = r.plan(cfg), r.plan(cfg)
    assert p1 is p2
    assert p1.config.executor in ("kernel", "reference")  # never "auto"
    assert isinstance(p1.t_prime, int) and p1.t_prime >= 1
    d = p1.describe()
    assert d["n_docs"] == idx.n_docs and d["gather"] == "materialize"
    # Planning the already-resolved config hits the same cache entry.
    assert r.plan(p1.config) is p1


def test_mesh_mismatch_rejected(setup):
    corpus, idx, *_ = setup
    with pytest.raises(ValueError, match="mesh"):
        Retriever.from_index(idx, mesh=jax.make_mesh((1,), ("data",)))


# ---- deprecated-flag shims ----

def test_legacy_flags_warn_and_map():
    with pytest.warns(DeprecationWarning, match="use_kernel"):
        c = WarpSearchConfig(use_kernel=True)
    assert c.executor == "kernel" and c.use_kernel is None
    with pytest.warns(DeprecationWarning, match="scan_qtokens"):
        c = WarpSearchConfig(scan_qtokens=True)
    assert c.memory == "scan_qtokens"
    with pytest.warns(DeprecationWarning, match="fused_gather"):
        c = WarpSearchConfig(fused_gather=True)
    assert c.gather == "fused"
    with pytest.warns(DeprecationWarning):
        c = WarpSearchConfig(use_kernel=False)
    assert c.executor == "reference"


def test_legacy_flags_hash_equal_to_strategy_spelling():
    with pytest.warns(DeprecationWarning):
        old = WarpSearchConfig(nprobe=4, fused_gather=True, scan_qtokens=True)
    new = WarpSearchConfig(nprobe=4, gather="fused", memory="scan_qtokens")
    assert old == new and hash(old) == hash(new)


def test_legacy_flagged_search_still_works(setup):
    _, idx, q, qmask, _ = setup
    with pytest.warns(DeprecationWarning):
        cfg_old = WarpSearchConfig(nprobe=8, k=10, t_prime=600, fused_gather=True)
    cfg_new = WarpSearchConfig(nprobe=8, k=10, t_prime=600, gather="fused")
    a = search(idx, q[0], jnp.asarray(qmask[0]), cfg_old)
    b = search(idx, q[0], jnp.asarray(qmask[0]), cfg_new)
    np.testing.assert_array_equal(np.asarray(a.doc_ids), np.asarray(b.doc_ids))


# ---- 2-shard shard_map parity (forced multi-device subprocess) ----

TWO_SHARD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import numpy as np, jax, jax.numpy as jnp
from repro.core import (Retriever, WarpSearchConfig, IndexBuildConfig,
                        build_sharded_index, sharded_search, build_index, search)
from repro.data import make_corpus, make_queries

corpus = make_corpus(n_docs=300, mean_doc_len=16, seed=0)
q, qmask, rel = make_queries(corpus, n_queries=6, seed=1)
sidx = build_sharded_index(corpus.emb, corpus.token_doc_ids, corpus.n_docs, 2,
                           IndexBuildConfig(n_centroids=32, nbits=4, kmeans_iters=3))
r = Retriever.from_index(sidx)
cfg_mat = WarpSearchConfig(nprobe=16, k=10, t_prime=1500, k_impute=32)
cfg_fused = WarpSearchConfig(nprobe=16, k=10, t_prime=1500, k_impute=32, gather="fused")
plan_mat, plan_fused = r.plan(cfg_mat), r.plan(cfg_fused)
assert plan_mat.n_shards == 2

# (a) fused == materialize under the 2-shard mesh, exactly.
for i in range(6):
    a = plan_mat.retrieve(q[i], qmask[i])
    b = plan_fused.retrieve(q[i], qmask[i])
    np.testing.assert_allclose(np.asarray(a.scores), np.asarray(b.scores),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(a.doc_ids), np.asarray(b.doc_ids))

# (b) Retriever == legacy sharded_search entry point, exactly.
for i in range(3):
    a = plan_fused.retrieve(q[i], qmask[i])
    b = sharded_search(sidx, q[i], jnp.asarray(qmask[i]), cfg_fused)
    np.testing.assert_array_equal(np.asarray(a.doc_ids), np.asarray(b.doc_ids))

# (c) vs single-device search over the SAME corpus: per-shard k-means gives a
# different codec, so scores differ — but retrieval quality must agree: the
# planted relevant doc is found by both paths.
idx = build_index(corpus.emb, corpus.token_doc_ids, corpus.n_docs,
                  IndexBuildConfig(n_centroids=64, nbits=4, kmeans_iters=3))
single_plan = Retriever.from_index(idx).plan(cfg_fused)
hits_sharded = hits_single = 0
for i in range(6):
    hits_sharded += int(rel[i] in np.asarray(plan_fused.retrieve(q[i], qmask[i]).doc_ids))
    hits_single += int(rel[i] in np.asarray(single_plan.retrieve(q[i], qmask[i]).doc_ids))
assert hits_single >= 5, hits_single
assert hits_sharded >= 5, hits_sharded
print("OK", hits_sharded, hits_single)
"""


@pytest.mark.slow
def test_two_shard_fused_parity_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-c", TWO_SHARD_SCRIPT],
        capture_output=True,
        text=True,
        env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=600,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout


# ---- per-request k-laddered config resolution ----


def test_k_ladder_goldens():
    """Golden ladder: requested k -> (nprobe, k_impute, t')."""
    from repro.core.retriever import ladder_rung, laddered_config

    small = laddered_config(10, n_tokens=1000, n_centroids=256)
    assert (small.nprobe, small.k_impute) == (16, 32)
    assert small.t_prime == int(0.5 * 1000**0.5)
    medium = laddered_config(100, n_tokens=1000, n_centroids=256)
    assert (medium.nprobe, medium.k_impute) == (32, 64)
    large = laddered_config(1000, n_tokens=1000, n_centroids=256)
    assert (large.nprobe, large.k_impute) == (64, 128)
    assert ladder_rung(10)[0] == "small"
    assert ladder_rung(11)[0] == "medium"
    assert ladder_rung(100)[0] == "medium"
    assert ladder_rung(101)[0] == "large"
    # Laddered nprobe never exceeds the index's centroid count.
    tiny = laddered_config(1000, n_tokens=1000, n_centroids=48)
    assert tiny.nprobe == 48


def test_k_ladder_explicit_config_beats_ladder():
    """Override precedence: any field pinned away from its dataclass
    default wins over the ladder value for that field — the ladder only
    fills defaults."""
    from repro.core.retriever import laddered_config

    pinned = laddered_config(
        10, WarpSearchConfig(nprobe=8), n_tokens=1000, n_centroids=256
    )
    assert pinned.nprobe == 8  # explicit wins
    assert pinned.k_impute == 32  # unpinned field still laddered
    both = laddered_config(
        10, WarpSearchConfig(nprobe=8, k_impute=96, t_prime=333),
        n_tokens=1000, n_centroids=256,
    )
    assert (both.nprobe, both.k_impute, both.t_prime) == (8, 96, 333)


def test_plan_for_k_describe_and_fingerprints(setup):
    """k=10 and k=100 plans resolve different rungs, expose them in
    describe(), and carry distinct fingerprints (the serving cache must
    never alias them)."""
    _, idx, *_ = setup
    r = Retriever.from_index(idx)
    p10 = r.plan_for_k(10)
    p100 = r.plan_for_k(100)
    assert p10.describe()["k_ladder"] == "small"
    assert p100.describe()["k_ladder"] == "medium"
    assert p10.fingerprint() != p100.fingerprint()
    assert p10.config.nprobe < p100.config.nprobe
