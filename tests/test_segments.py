"""Delta segments: add_documents -> segmented search -> compact().

Correctness anchors:
  1. segmented search (base + deltas) retrieves the SAME documents as the
     compacted single-segment index, scores equal up to fp summation order
     — shared stage-1 over combined cluster sizes makes the decomposition
     exact, not approximate;
  2. after add_documents, retrieval matches a from-scratch rebuild of the
     concatenated corpus (top-k ids equal) on margin queries — queries
     whose top-k doc-score gaps are O(1), far above codec noise, so the
     comparison is meaningful across two different clusterings;
  3. compact() preserves doc ids/scores and drops the segment dirs.
"""

import numpy as np
import pytest

from repro.core import (
    IndexBuildConfig,
    Retriever,
    WarpSearchConfig,
    build_index,
    index_stats,
)
from repro.data import make_corpus
from repro.store import (
    SegmentedWarpIndex,
    add_documents,
    compact,
    list_segment_dirs,
    load_index,
    save_index,
)

BUILD_CFG = IndexBuildConfig(n_centroids=64, nbits=4, kmeans_iters=3)
DIM = 128


def concat_corpora(c1, c2):
    emb = np.concatenate([c1.emb, c2.emb])
    tdi = np.concatenate([c1.token_doc_ids, c2.token_doc_ids + c1.n_docs])
    return emb, tdi, c1.n_docs + c2.n_docs


def margin_queries(emb, tdi, n_docs, n_queries, seed):
    """Queries built from 4/3/2 near-copies of tokens from three distinct
    docs: the top-3 docs and their order are decided by token multiplicity
    (score gaps ~1.0), not by codec- or imputation-level noise."""
    rng = np.random.default_rng(seed)
    offs = {}
    for t, d in enumerate(tdi):
        offs.setdefault(int(d), []).append(t)
    qs, masks, expected = [], [], []
    for _ in range(n_queries):
        docs = rng.choice(n_docs, size=3, replace=False)
        toks = []
        for mult, d in zip((4, 3, 2), docs):
            cand = offs[int(d)]
            pick = rng.choice(cand, size=mult, replace=len(cand) < mult)
            toks.extend(emb[pick])
        arr = np.stack(toks) + 0.01 * rng.standard_normal((9, DIM)).astype(
            np.float32
        )
        qs.append(arr / np.linalg.norm(arr, axis=-1, keepdims=True))
        masks.append(np.ones(9, bool))
        expected.append(docs)
    return np.stack(qs).astype(np.float32), np.stack(masks), expected


@pytest.fixture(scope="module")
def lifecycle(tmp_path_factory):
    """Base corpus saved to a store + one delta of new documents."""
    c1 = make_corpus(n_docs=160, mean_doc_len=14, seed=31,
                     topic_strength=3.0, n_topics=200)
    c2 = make_corpus(n_docs=40, mean_doc_len=14, seed=32,
                     topic_strength=3.0, n_topics=200)
    path = str(tmp_path_factory.mktemp("store") / "idx")
    base = build_index(c1.emb, c1.token_doc_ids, c1.n_docs, BUILD_CFG)
    save_index(base, path, build_config=BUILD_CFG)
    add_documents(path, c2.emb, c2.token_doc_ids, c2.n_docs)
    return c1, c2, path


def test_add_documents_appends_segment(lifecycle):
    c1, c2, path = lifecycle
    seg = load_index(path)
    assert isinstance(seg, SegmentedWarpIndex)
    assert seg.n_segments == 2
    assert seg.n_docs == c1.n_docs + c2.n_docs
    assert seg.n_tokens == c1.n_tokens + c2.n_tokens
    assert seg.doc_starts == (0, c1.n_docs)
    # The delta shares the frozen centroid space, not a re-clustered one.
    delta = seg.deltas[0]
    assert delta.n_centroids == seg.base.n_centroids
    assert np.shares_memory(
        np.asarray(delta.centroids), np.asarray(seg.base.centroids)
    ) or np.array_equal(
        np.asarray(delta.centroids), np.asarray(seg.base.centroids)
    )
    sizes = np.asarray(seg.combined_cluster_sizes())
    assert sizes.sum() == seg.n_tokens


def test_segmented_search_reaches_both_old_and_new_docs(lifecycle):
    c1, c2, path = lifecycle
    emb, tdi, n_docs = concat_corpora(c1, c2)
    plan = Retriever.from_store(path).plan(WarpSearchConfig(nprobe=16, k=3))
    q, m, expected = margin_queries(emb, tdi, n_docs, 8, seed=77)
    hits = 0
    for i in range(q.shape[0]):
        got = np.asarray(plan.retrieve(q[i], m[i]).doc_ids)
        hits += int(expected[i][0] == got[0])
    assert hits == q.shape[0]
    # Queries specifically about delta documents retrieve global ids.
    q2, m2, exp2 = margin_queries(c2.emb, c2.token_doc_ids, c2.n_docs, 4, seed=78)
    for i in range(2):
        got = np.asarray(plan.retrieve(q2[i], m2[i]).doc_ids)
        assert got[0] == exp2[i][0] + c1.n_docs


def test_segmented_matches_rebuild_on_concatenated_corpus(lifecycle):
    """Acceptance: add_documents + search == from-scratch rebuild of the
    concatenated corpus, top-k ids equal (margin queries; full probing so
    imputation cancels and only O(1) score gaps decide)."""
    c1, c2, path = lifecycle
    emb, tdi, n_docs = concat_corpora(c1, c2)
    cfg = WarpSearchConfig(nprobe=64, k=3)
    plan_seg = Retriever.from_store(path).plan(cfg)
    rebuilt = build_index(emb, tdi, n_docs, BUILD_CFG)
    plan_re = Retriever.from_index(rebuilt).plan(cfg)
    q, m, _ = margin_queries(emb, tdi, n_docs, 10, seed=36)
    for i in range(q.shape[0]):
        a = np.asarray(plan_seg.retrieve(q[i], m[i]).doc_ids)
        b = np.asarray(plan_re.retrieve(q[i], m[i]).doc_ids)
        np.testing.assert_array_equal(a, b)


def test_compact_preserves_results(lifecycle, tmp_path):
    """compact() must not change retrieval: same docs in the same order;
    scores equal up to the reduction's fp summation order (the scan tree
    shape depends on candidate-array length, so allow the last ulps)."""
    import shutil

    c1, c2, path = lifecycle
    work = str(tmp_path / "idx")
    shutil.copytree(path, work)
    emb, tdi, n_docs = concat_corpora(c1, c2)
    cfg = WarpSearchConfig(nprobe=16, k=3)
    plan_seg = Retriever.from_store(work).plan(cfg)
    q, m, _ = margin_queries(emb, tdi, n_docs, 6, seed=55)
    before = [plan_seg.retrieve(q[i], m[i]) for i in range(q.shape[0])]
    before_batch = plan_seg.retrieve_batch(q, m)

    compact(work)
    assert list_segment_dirs(work) == []
    comp = load_index(work)
    assert not isinstance(comp, SegmentedWarpIndex)
    stats = index_stats(comp)
    assert stats["n_docs"] == n_docs and stats["n_tokens"] == len(tdi)

    plan_c = Retriever.from_store(work).plan(cfg)
    for i, r in enumerate(before):
        rc = plan_c.retrieve(q[i], m[i])
        np.testing.assert_array_equal(
            np.asarray(r.doc_ids), np.asarray(rc.doc_ids)
        )
        np.testing.assert_allclose(
            np.asarray(r.scores), np.asarray(rc.scores), rtol=1e-6, atol=1e-6
        )
    rcb = plan_c.retrieve_batch(q, m)
    np.testing.assert_array_equal(
        np.asarray(before_batch.doc_ids), np.asarray(rcb.doc_ids)
    )
    # Compacting an already-compact store is a no-op.
    assert compact(work) == work


def test_multiple_deltas_then_compact(tmp_path):
    """Two append rounds stack segments; compaction folds both."""
    c1 = make_corpus(n_docs=80, mean_doc_len=10, seed=41)
    c2 = make_corpus(n_docs=20, mean_doc_len=10, seed=42)
    c3 = make_corpus(n_docs=15, mean_doc_len=10, seed=43)
    cfg = IndexBuildConfig(n_centroids=16, nbits=4, kmeans_iters=2)
    path = str(tmp_path / "idx")
    save_index(build_index(c1.emb, c1.token_doc_ids, c1.n_docs, cfg), path,
               build_config=cfg)
    add_documents(path, c2.emb, c2.token_doc_ids, c2.n_docs)
    add_documents(path, c3.emb, c3.token_doc_ids, c3.n_docs)
    seg = load_index(path)
    assert seg.n_segments == 3
    assert seg.doc_starts == (0, c1.n_docs, c1.n_docs + c2.n_docs)

    emb = np.concatenate([c1.emb, c2.emb, c3.emb])
    tdi = np.concatenate([
        c1.token_doc_ids,
        c2.token_doc_ids + c1.n_docs,
        c3.token_doc_ids + c1.n_docs + c2.n_docs,
    ])
    n_docs = c1.n_docs + c2.n_docs + c3.n_docs
    scfg = WarpSearchConfig(nprobe=8, k=3, t_prime=300)
    q, m, _ = margin_queries(emb, tdi, n_docs, 4, seed=44)
    plan_a = Retriever.from_store(path).plan(scfg)
    before = [plan_a.retrieve(q[i], m[i]) for i in range(q.shape[0])]
    compact(path)
    plan_b = Retriever.from_store(path).plan(scfg)
    for i, a in enumerate(before):
        b = plan_b.retrieve(q[i], m[i])
        np.testing.assert_array_equal(
            np.asarray(a.doc_ids), np.asarray(b.doc_ids)
        )
        np.testing.assert_allclose(
            np.asarray(a.scores), np.asarray(b.scores), rtol=1e-6, atol=1e-6
        )


def test_add_documents_validates_inputs(lifecycle, tmp_path):
    c1, c2, path = lifecycle
    with pytest.raises(ValueError, match="local"):
        add_documents(path, c2.emb, c2.token_doc_ids + c1.n_docs, c2.n_docs)
    with pytest.raises(ValueError, match="align"):
        add_documents(path, c2.emb, c2.token_doc_ids[:-1], c2.n_docs)
