"""Serving stack: generation loop + retrieval request batcher."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import IndexBuildConfig, WarpSearchConfig, build_index, search
from repro.data import make_corpus, make_queries
from repro.models.transformer import TransformerConfig, TransformerLM
from repro.serving import BatchPolicy, RetrievalServer, generate


def test_generate_matches_forward_greedy():
    cfg = TransformerConfig(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=64,
        head_dim=16, compute_dtype="float32",
    )
    params = TransformerLM.init(jax.random.PRNGKey(0), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 10), 0, 64)
    out = generate(params, cfg, prompt, max_new_tokens=4, cache_dtype=jnp.float32)
    assert out.shape == (2, 4)
    # Greedy step 1 must equal argmax of forward logits at the last position.
    hid, _ = TransformerLM.forward(params, cfg, prompt)
    lg = TransformerLM.logits(params, cfg, hid)[:, -1, :]
    np.testing.assert_array_equal(np.asarray(out[:, 0]), np.asarray(jnp.argmax(lg, -1)))


def test_generate_temperature_shapes():
    cfg = TransformerConfig(
        n_layers=1, d_model=32, n_heads=2, n_kv_heads=2, d_ff=64, vocab=32,
        head_dim=16, compute_dtype="float32",
    )
    params = TransformerLM.init(jax.random.PRNGKey(0), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (3, 5), 0, 32)
    out = generate(params, cfg, prompt, max_new_tokens=3, temperature=0.8,
                   key=jax.random.PRNGKey(2), cache_dtype=jnp.float32)
    assert out.shape == (3, 3)
    assert (np.asarray(out) >= 0).all() and (np.asarray(out) < 32).all()


class _FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def _server(policy):
    corpus = make_corpus(n_docs=150, mean_doc_len=12, seed=0)
    idx = build_index(
        corpus.emb, corpus.token_doc_ids, corpus.n_docs,
        IndexBuildConfig(n_centroids=32, nbits=4, kmeans_iters=2),
    )
    q, qmask, rel = make_queries(corpus, n_queries=10, seed=1)
    clock = _FakeClock()
    srv = RetrievalServer(
        idx, WarpSearchConfig(nprobe=8, k=5), policy, clock=clock
    )
    return srv, clock, q, qmask, rel, idx


def test_batcher_dispatches_when_full():
    srv, clock, q, qmask, rel, idx = _server(BatchPolicy(max_batch=4, max_wait_s=10.0))
    ids = [srv.submit(q[i], qmask[i]) for i in range(4)]
    served = srv.step()
    assert served == 4
    for i, rid in enumerate(ids):
        scores, docs = srv.poll(rid)
        assert scores.shape == (5,)
        # batched result equals single-query search
        single = search(idx, q[i], jnp.asarray(qmask[i]), WarpSearchConfig(nprobe=8, k=5))
        np.testing.assert_array_equal(docs, np.asarray(single.doc_ids))


def test_batcher_deadline_fires_partial_batch():
    srv, clock, q, qmask, *_ = _server(BatchPolicy(max_batch=8, max_wait_s=0.005))
    srv.submit(q[0], qmask[0])
    assert srv.step() == 0  # not full, deadline not reached
    clock.t += 0.01
    assert srv.step() == 1  # deadline expired -> padded dispatch
    assert srv.stats["padded_slots"] == 7


def test_batcher_drain():
    srv, clock, q, qmask, *_ = _server(BatchPolicy(max_batch=4, max_wait_s=10.0))
    ids = [srv.submit(q[i], qmask[i]) for i in range(6)]
    srv.drain()
    assert all(srv.poll(r) is not None for r in ids)
    assert srv.stats["served"] == 6
