"""Serving stack: generation loop + retrieval request batcher."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import IndexBuildConfig, WarpSearchConfig, build_index, search
from repro.data import make_corpus, make_queries
from repro.models.transformer import TransformerConfig, TransformerLM
from repro.serving import PENDING, BatchPolicy, RetrievalServer, generate


def test_generate_matches_forward_greedy():
    cfg = TransformerConfig(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=64,
        head_dim=16, compute_dtype="float32",
    )
    params = TransformerLM.init(jax.random.PRNGKey(0), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 10), 0, 64)
    out = generate(params, cfg, prompt, max_new_tokens=4, cache_dtype=jnp.float32)
    assert out.shape == (2, 4)
    # Greedy step 1 must equal argmax of forward logits at the last position.
    hid, _ = TransformerLM.forward(params, cfg, prompt)
    lg = TransformerLM.logits(params, cfg, hid)[:, -1, :]
    np.testing.assert_array_equal(np.asarray(out[:, 0]), np.asarray(jnp.argmax(lg, -1)))


def test_generate_temperature_shapes():
    cfg = TransformerConfig(
        n_layers=1, d_model=32, n_heads=2, n_kv_heads=2, d_ff=64, vocab=32,
        head_dim=16, compute_dtype="float32",
    )
    params = TransformerLM.init(jax.random.PRNGKey(0), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (3, 5), 0, 32)
    out = generate(params, cfg, prompt, max_new_tokens=3, temperature=0.8,
                   key=jax.random.PRNGKey(2), cache_dtype=jnp.float32)
    assert out.shape == (3, 3)
    assert (np.asarray(out) >= 0).all() and (np.asarray(out) < 32).all()


class _FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def _server(policy):
    corpus = make_corpus(n_docs=150, mean_doc_len=12, seed=0)
    idx = build_index(
        corpus.emb, corpus.token_doc_ids, corpus.n_docs,
        IndexBuildConfig(n_centroids=32, nbits=4, kmeans_iters=2),
    )
    q, qmask, rel = make_queries(corpus, n_queries=10, seed=1)
    clock = _FakeClock()
    srv = RetrievalServer(
        idx, WarpSearchConfig(nprobe=8, k=5), policy, clock=clock
    )
    return srv, clock, q, qmask, rel, idx


def test_batcher_dispatches_when_full():
    srv, clock, q, qmask, rel, idx = _server(BatchPolicy(max_batch=4, max_wait_s=10.0))
    ids = [srv.submit(q[i], qmask[i]) for i in range(4)]
    served = srv.step()
    assert served == 4
    for i, rid in enumerate(ids):
        scores, docs = srv.poll(rid)
        assert scores.shape == (5,)
        # batched result equals single-query search
        single = search(idx, q[i], jnp.asarray(qmask[i]), WarpSearchConfig(nprobe=8, k=5))
        np.testing.assert_array_equal(docs, np.asarray(single.doc_ids))


def test_batcher_deadline_fires_partial_batch():
    srv, clock, q, qmask, *_ = _server(BatchPolicy(max_batch=8, max_wait_s=0.005))
    srv.submit(q[0], qmask[0])
    assert srv.step() == 0  # not full, deadline not reached
    clock.t += 0.01
    assert srv.step() == 1  # deadline expired -> padded dispatch
    assert srv.stats["padded_slots"] == 7


def test_batcher_drain():
    srv, clock, q, qmask, *_ = _server(BatchPolicy(max_batch=4, max_wait_s=10.0))
    ids = [srv.submit(q[i], qmask[i]) for i in range(6)]
    srv.drain()
    assert all(srv.poll(r) is not PENDING for r in ids)
    assert srv.stats["served"] == 6


def test_poll_pending_sentinel_is_not_destructive():
    srv, clock, q, qmask, *_ = _server(BatchPolicy(max_batch=8, max_wait_s=10.0))
    rid = srv.submit(q[0], qmask[0])
    # Pending: repeated polls keep returning the sentinel (nothing popped).
    assert srv.poll(rid) is PENDING
    assert srv.poll(rid) is PENDING
    assert not PENDING  # falsy, so `if result:` reads naturally
    srv.step(force=True)
    scores, docs = srv.poll(rid)
    assert scores.shape == (5,)
    # Consumed exactly once: a second poll is now an *unknown* id.
    with pytest.raises(KeyError):
        srv.poll(rid)


def test_poll_unknown_id_raises():
    srv, *_ = _server(BatchPolicy(max_batch=4, max_wait_s=10.0))
    with pytest.raises(KeyError):
        srv.poll(12345)


def test_result_blocks_until_served_and_matches_single():
    srv, clock, q, qmask, rel, idx = _server(BatchPolicy(max_batch=8, max_wait_s=10.0))
    rid = srv.submit(q[0], qmask[0])
    # result() drives the loop itself: no manual step()/drain() needed.
    scores, docs = srv.result(rid)
    single = search(idx, q[0], jnp.asarray(qmask[0]), WarpSearchConfig(nprobe=8, k=5))
    np.testing.assert_array_equal(docs, np.asarray(single.doc_ids))
    with pytest.raises(KeyError):
        srv.result(rid)  # already consumed


def test_result_timeout_on_empty_progress():
    srv, clock, q, qmask, *_ = _server(BatchPolicy(max_batch=8, max_wait_s=10.0))
    rid = srv.submit(q[0], qmask[0])
    consumed = srv.result(rid, timeout=5.0)
    assert consumed is not PENDING
    # Unknown id: KeyError wins over timeout.
    with pytest.raises(KeyError):
        srv.result(999, timeout=0.1)


def test_result_timeout_fires_and_preserves_request():
    srv, clock, q, qmask, *_ = _server(BatchPolicy(max_batch=8, max_wait_s=10.0))
    rid = srv.submit(q[0], qmask[0])
    # An already-exhausted budget must raise before any forced dispatch...
    with pytest.raises(TimeoutError):
        srv.result(rid, timeout=0.0)
    # ...leaving the request pending and still servable afterwards.
    assert srv.poll(rid) is PENDING
    scores, docs = srv.result(rid)
    assert scores.shape == (5,)


def test_server_accepts_sharded_index():
    """End-to-end sharded serving: same batcher, document-sharded plan."""
    from repro.core import Retriever, build_sharded_index

    corpus = make_corpus(n_docs=120, mean_doc_len=10, seed=2)
    sidx = build_sharded_index(
        corpus.emb, corpus.token_doc_ids, corpus.n_docs,
        n_shards=len(jax.devices()),
        config=IndexBuildConfig(n_centroids=16, nbits=4, kmeans_iters=2),
    )
    q, qmask, rel = make_queries(corpus, n_queries=6, seed=3)
    srv = RetrievalServer(
        Retriever.from_index(sidx),
        WarpSearchConfig(nprobe=8, k=5, t_prime=400),
        BatchPolicy(max_batch=4, max_wait_s=10.0),
    )
    assert srv.plan.n_shards == len(jax.devices())
    ids = [srv.submit(q[i], qmask[i]) for i in range(6)]
    hits = 0
    for i, rid in enumerate(ids):
        scores, docs = srv.result(rid, timeout=30.0)
        assert scores.shape == (5,)
        hits += int(rel[i] in docs)
    assert hits >= 4
    assert srv.stats["served"] == 6


def test_server_reload_hot_swaps_index(tmp_path):
    """Lifecycle: serve a store-backed index, add docs + compact offline,
    reload() — queued requests survive, new docs become retrievable, and
    t' re-resolves against the grown corpus."""
    from repro.core import Retriever
    from repro.store import add_documents, compact, save_index

    c1 = make_corpus(n_docs=120, mean_doc_len=10, seed=4)
    c2 = make_corpus(n_docs=30, mean_doc_len=10, seed=5)
    cfg = IndexBuildConfig(n_centroids=16, nbits=4, kmeans_iters=2)
    path = str(tmp_path / "idx")
    save_index(build_index(c1.emb, c1.token_doc_ids, c1.n_docs, cfg), path,
               build_config=cfg)

    clock = _FakeClock()
    srv = RetrievalServer(
        Retriever.from_store(path),
        WarpSearchConfig(nprobe=8, k=5),  # t' left data-dependent on purpose
        BatchPolicy(max_batch=4, max_wait_s=10.0),
        clock=clock,
    )
    t_prime_before = srv.plan.config.t_prime
    assert srv.retriever.n_docs == c1.n_docs

    # A request queued BEFORE the reload must be served by the new plan.
    queued = srv.submit(np.asarray(c2.emb[:4], np.float32), np.ones(4, bool))

    add_documents(path, c2.emb, c2.token_doc_ids, c2.n_docs)
    compact(path)
    srv.reload(path)
    assert srv.stats["reloads"] == 1
    assert srv.retriever.n_docs == c1.n_docs + c2.n_docs
    # t' re-resolved from the grown token count, not frozen from the old.
    assert srv.plan.config.t_prime >= t_prime_before

    scores, docs = srv.result(queued, timeout=30.0)
    assert docs.shape == (5,)
    # The query was doc 0 of the delta batch: its global id must surface.
    assert c1.n_docs + 0 in docs
    # Fresh requests keep flowing on the same server object.
    rid = srv.submit(np.asarray(c1.emb[:4], np.float32), np.ones(4, bool))
    scores, docs = srv.result(rid, timeout=30.0)
    assert docs.shape == (5,)
