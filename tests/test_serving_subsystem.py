"""Production serving subsystem: bucket-aware continuous batching,
two-level caching, admission control, background compaction.

The load-bearing claim is EXACTNESS: bucket-aware scheduling (per-rung
batches, backfill, promotion) and result caching are pure dispatch-order
optimizations — every served result must carry the same doc ids as a
direct ``plan.retrieve`` of that query (scores equal to float32
summation order, since a batch may dispatch at a larger ladder rung than
the query's own). Verified across local / sharded / segmented plans with
caching on and off.
"""

import jax
import numpy as np
import pytest

from repro.core import (
    IndexBuildConfig,
    Retriever,
    WarpSearchConfig,
    build_index,
    build_sharded_index,
)
from repro.data import make_corpus, make_queries
from repro.serving import (
    PENDING,
    AdmissionPolicy,
    BatchPolicy,
    BucketScheduler,
    CompactionPolicy,
    Overloaded,
    ResultAlreadyTaken,
    RetrievalServer,
)

RAGGED = WarpSearchConfig(nprobe=8, k=5, t_prime=400, layout="ragged")


class _FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


@pytest.fixture(scope="module")
def corpus():
    return make_corpus(n_docs=250, mean_doc_len=12, seed=0)


@pytest.fixture(scope="module")
def queries(corpus):
    # Varied active lengths spread adaptive worklist demand across rungs.
    q, qmask, rel = make_queries(
        corpus, n_queries=10, tokens_per_query=(2, 24), seed=1
    )
    return q, qmask, rel


@pytest.fixture(scope="module")
def local_retriever(corpus):
    return Retriever.from_index(
        build_index(
            corpus.emb, corpus.token_doc_ids, corpus.n_docs,
            IndexBuildConfig(n_centroids=32, nbits=4, kmeans_iters=2),
        )
    )


def _serve_all(retriever, q, qmask, *, cache_size, n=8):
    clock = _FakeClock()
    srv = RetrievalServer(
        retriever, RAGGED, BatchPolicy(max_batch=4, max_wait_s=10.0),
        clock=clock, bucket_aware=True, cache_size=cache_size,
    )
    ids = [srv.submit(q[i], qmask[i]) for i in range(n)]
    srv.drain()
    return srv, ids


def _assert_matches_direct(srv, ids, q, qmask):
    for i, rid in enumerate(ids):
        scores, docs = srv.poll(rid)
        direct = srv.plan.retrieve(q[i], qmask[i])
        np.testing.assert_array_equal(docs, np.asarray(direct.doc_ids))
        np.testing.assert_allclose(
            scores, np.asarray(direct.scores), rtol=1e-5, atol=1e-6
        )


@pytest.mark.parametrize("cache_size", [0, 64])
def test_bucket_aware_exactness_local(local_retriever, queries, cache_size):
    q, qmask, _ = queries
    srv, ids = _serve_all(local_retriever, q, qmask, cache_size=cache_size)
    _assert_matches_direct(srv, ids, q, qmask)
    # Varied-length traffic must actually spread across ladder rungs —
    # otherwise this test degenerates to the single-FIFO batcher.
    assert len(srv.summary()["rungs"]) >= 2


@pytest.mark.parametrize("cache_size", [0, 64])
def test_bucket_aware_exactness_sharded(corpus, queries, cache_size):
    sidx = build_sharded_index(
        corpus.emb, corpus.token_doc_ids, corpus.n_docs,
        n_shards=len(jax.devices()),
        config=IndexBuildConfig(n_centroids=16, nbits=4, kmeans_iters=2),
    )
    q, qmask, _ = queries
    srv, ids = _serve_all(
        Retriever.from_index(sidx), q, qmask, cache_size=cache_size, n=6
    )
    _assert_matches_direct(srv, ids, q, qmask)


@pytest.mark.parametrize("cache_size", [0, 64])
def test_bucket_aware_exactness_segmented(tmp_path, cache_size):
    from repro.store import add_documents, save_index

    c1 = make_corpus(n_docs=150, mean_doc_len=10, seed=4)
    c2 = make_corpus(n_docs=40, mean_doc_len=10, seed=5)
    cfg = IndexBuildConfig(n_centroids=16, nbits=4, kmeans_iters=2)
    path = str(tmp_path / "idx")
    save_index(build_index(c1.emb, c1.token_doc_ids, c1.n_docs, cfg), path,
               build_config=cfg)
    add_documents(path, c2.emb, c2.token_doc_ids, c2.n_docs)  # stays delta

    q, qmask, _ = make_queries(c1, n_queries=6, tokens_per_query=(2, 20),
                               seed=6)
    srv, ids = _serve_all(
        Retriever.from_store(path), q, qmask, cache_size=cache_size, n=6
    )
    _assert_matches_direct(srv, ids, q, qmask)


def test_cache_hit_bit_identical_to_miss(local_retriever, queries):
    """A result-cache hit must return byte-for-byte what a cache miss
    computes for the same (query, plan fingerprint, index epoch)."""
    q, qmask, _ = queries
    clock = _FakeClock()

    def fresh(cache_size):
        return RetrievalServer(
            local_retriever, RAGGED,
            BatchPolicy(max_batch=4, max_wait_s=10.0),
            clock=clock, bucket_aware=True, cache_size=cache_size,
        )

    warm = fresh(64)
    cold = fresh(0)
    for i in range(4):
        # Serve each query alone in both servers so the only variable is
        # the cache, then re-submit to the warm server: a guaranteed hit.
        r_seed = warm.submit(q[i], qmask[i])
        warm.drain()
        warm.poll(r_seed)
        r_hit = warm.submit(q[i], qmask[i])
        hs, hd = warm.poll(r_hit)  # completed at submit: no drain needed
        r_miss = cold.submit(q[i], qmask[i])
        cold.drain()
        ms_, md = cold.poll(r_miss)
        np.testing.assert_array_equal(hd, md)
        np.testing.assert_array_equal(hs, ms_)
    assert warm.result_cache.stats()["hits"] == 4


def test_cache_invalidation_across_reload(tmp_path):
    """Warm cache -> add_documents + compact + reload: the epoch bumps,
    stale entries are purged, and the same query re-executes against the
    grown index (new delta doc retrievable, not a stale cached answer)."""
    from repro.store import add_documents, compact, save_index

    c1 = make_corpus(n_docs=120, mean_doc_len=10, seed=4)
    c2 = make_corpus(n_docs=30, mean_doc_len=10, seed=5)
    cfg = IndexBuildConfig(n_centroids=16, nbits=4, kmeans_iters=2)
    path = str(tmp_path / "idx")
    save_index(build_index(c1.emb, c1.token_doc_ids, c1.n_docs, cfg), path,
               build_config=cfg)

    clock = _FakeClock()
    srv = RetrievalServer(
        Retriever.from_store(path), WarpSearchConfig(nprobe=8, k=5),
        BatchPolicy(max_batch=4, max_wait_s=10.0), clock=clock,
        cache_size=64,
    )
    # The query is doc 0 of the (future) delta batch — pre-reload it can't
    # surface, post-reload it must.
    qv = np.asarray(c2.emb[:4], np.float32)
    qm = np.ones(4, bool)
    rid = srv.submit(qv, qm)
    srv.drain()
    _, docs_before = srv.poll(rid)
    assert c1.n_docs not in docs_before
    assert srv.result_cache.stats()["size"] == 1
    epoch_before = srv.index_epoch

    add_documents(path, c2.emb, c2.token_doc_ids, c2.n_docs)
    compact(path)
    srv.reload(path)
    assert srv.index_epoch == epoch_before + 1
    assert srv.result_cache.stats()["size"] == 0  # stale epoch purged

    rid = srv.submit(qv, qm)
    assert srv.result_cache.stats()["hits"] == 0  # NOT served from cache
    srv.drain()
    _, docs_after = srv.poll(rid)
    assert c1.n_docs + 0 in docs_after


def test_background_compaction_trigger(tmp_path):
    """maintain() compacts + reloads when the delta share crosses the
    policy threshold, and is a no-op below it / inside min_interval_s."""
    from repro.store import add_documents, list_segment_dirs, save_index

    c1 = make_corpus(n_docs=120, mean_doc_len=10, seed=4)
    c2 = make_corpus(n_docs=60, mean_doc_len=10, seed=5)
    cfg = IndexBuildConfig(n_centroids=16, nbits=4, kmeans_iters=2)
    path = str(tmp_path / "idx")
    save_index(build_index(c1.emb, c1.token_doc_ids, c1.n_docs, cfg), path,
               build_config=cfg)

    clock = _FakeClock()
    clock.t = 100.0
    srv = RetrievalServer(
        Retriever.from_store(path), WarpSearchConfig(nprobe=8, k=5),
        BatchPolicy(max_batch=4, max_wait_s=10.0), clock=clock,
        compaction=CompactionPolicy(max_delta_segments=4,
                                    max_delta_frac=0.25,
                                    min_interval_s=30.0),
        store_path=path,
    )
    assert srv.maintain() is False  # no deltas yet

    add_documents(path, c2.emb, c2.token_doc_ids, c2.n_docs)  # ~33% delta
    clock.t += 31.0
    assert srv.maintain() is True
    assert srv.stats["compactions"] == 1
    assert srv.stats["reloads"] == 1
    assert list_segment_dirs(path) == []  # deltas folded into the base
    assert srv.retriever.n_docs == c1.n_docs + c2.n_docs
    clock.t += 1.0
    assert srv.maintain() is False  # inside min_interval_s


def test_admission_overload_sheds_and_bounds_latency():
    """Deterministic-clock overload: arrivals at ~2x the service rate must
    shed via Overloaded, and every ADMITTED request's latency stays under
    the queue-depth SLO bound — the bound the gate exists to enforce
    (admitted requests wait behind at most depth/batch batches plus the
    deadline, never behind an unbounded backlog)."""
    corpus = make_corpus(n_docs=100, mean_doc_len=10, seed=7)
    idx = build_index(
        corpus.emb, corpus.token_doc_ids, corpus.n_docs,
        IndexBuildConfig(n_centroids=16, nbits=4, kmeans_iters=2),
    )
    q, qmask, _ = make_queries(corpus, n_queries=8, seed=8)
    clock = _FakeClock()
    max_batch, depth, t_svc, max_wait = 4, 8, 0.01, 0.02
    srv = RetrievalServer(
        idx, WarpSearchConfig(nprobe=8, k=5),
        BatchPolicy(max_batch=max_batch, max_wait_s=max_wait),
        clock=clock, cache_size=0,
        admission=AdmissionPolicy(max_queue_depth=depth),
    )
    arrival: dict[int, float] = {}
    completion: dict[int, float] = {}
    shed = 0
    busy_until = 0.0  # the server executes one batch at a time

    def collect(at: float):
        for r in list(arrival):
            if r not in completion and srv.poll(r) is not PENDING:
                completion[r] = at

    def service(force=False):
        nonlocal busy_until
        while len(srv.scheduler):
            if clock.t < busy_until:
                return  # mid-batch; the queue keeps growing meanwhile
            d = srv.next_deadline()
            if not (force or len(srv.scheduler) >= max_batch
                    or (d is not None and clock.t >= d)):
                return
            srv.step(force=True)
            busy_until = clock.t + t_svc  # deterministic service time
            collect(busy_until)

    gap = 0.00125  # 800/s arrivals vs 400/s service capacity: 2x overload
    for kk in range(40):
        clock.t = kk * gap
        service()
        try:
            rid = srv.submit(q[kk % 8], qmask[kk % 8])
            arrival[rid] = clock.t
        except Overloaded:
            shed += 1
    while len(srv.scheduler):  # drain the admitted backlog
        clock.t = max(clock.t, busy_until)
        service(force=True)

    assert shed > 0
    assert srv.admission.shed == shed
    assert len(completion) == len(arrival)  # everything admitted served
    lat = [completion[r] - arrival[r] for r in arrival]
    # Depth-gate SLO: at most depth/max_batch full batches ahead plus the
    # in-flight batch plus the request's own, plus the deadline wait and
    # one arrival-gap of dispatch-check slack. The gate exists exactly so
    # this bound holds for every ADMITTED request no matter the offered
    # load (the shed ones are the ones that would have blown it).
    slo = max_wait + (depth // max_batch + 2) * t_svc + gap
    assert max(lat) <= slo + 1e-9


def test_scheduler_starvation_promotion():
    clock = _FakeClock()
    sched = BucketScheduler(
        BatchPolicy(max_batch=4, max_wait_s=10.0, promote_after_s=1.0),
        clock, rungs=(2, 4, 8, 16),
    )

    class Item:
        def __init__(self, name, arrival):
            self.name, self.arrival = name, arrival

    sched.push(Item("old", 0.0), rung=2)
    clock.t = 2.0  # "old" is now stale past promote_after_s
    for j in range(3):
        sched.push(Item(f"new{j}", 2.0), rung=8)
    # Nothing full or past deadline yet — but the promotion pass ran.
    assert sched.next_batch() is None
    assert sched.stats["promoted"] == 1
    # The climb is a per-interval ratchet: re-checking at the same
    # instant must NOT promote again (no cascade to the top rung).
    assert sched.next_batch() is None
    assert sched.stats["promoted"] == 1
    rung, items = sched.next_batch(force=True)
    # The stale rung-2 item now sits at rung 4 and, being the most
    # overdue head, dispatches first (at rung 4 — still exact: 4 >= 2).
    assert rung == 4
    assert [i.name for i in items] == ["old"]
    rung2, items2 = sched.next_batch(force=True)
    assert rung2 == 8 and len(items2) == 3


def test_poll_already_taken_vs_never_submitted(local_retriever, queries):
    q, qmask, _ = queries
    clock = _FakeClock()
    srv = RetrievalServer(
        local_retriever, RAGGED, BatchPolicy(max_batch=2, max_wait_s=10.0),
        clock=clock,
    )
    rid = srv.submit(q[0], qmask[0])
    srv.drain()
    srv.poll(rid)
    with pytest.raises(ResultAlreadyTaken, match="already retrieved"):
        srv.poll(rid)
    # ResultAlreadyTaken subclasses KeyError (old callers keep working)...
    assert issubclass(ResultAlreadyTaken, KeyError)
    # ...but an id that was NEVER submitted is a plain KeyError with a
    # directed message, not ResultAlreadyTaken.
    with pytest.raises(KeyError, match="never submitted") as ei:
        srv.poll(10_000)
    assert not isinstance(ei.value, ResultAlreadyTaken)


# ---- benchmark-harness serving smoke (tier-1 schema guard) ----


def test_bench_serving_smoke(tmp_path):
    import os
    import sys

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    if root not in sys.path:
        sys.path.insert(0, root)
    import json

    from benchmarks import bench_serving, run as bench_run

    bench_serving.run(micro=True)
    snap_path = str(tmp_path / "BENCH_serving.json")
    bench_run.write_serving_snapshot(snap_path)
    with open(snap_path) as f:
        snap = json.load(f)
    assert snap["bench_schema"] >= 2
    assert all(r["name"].startswith("serving/") for r in snap["metrics"])
    full = snap["arms"]["cache_on_bucket_on"]
    for key in ("p50_ms", "p99_ms", "qps", "cache_hit_rate", "shed_frac",
                "rung_occupancy"):
        assert key in full
    assert full["cache_hit_rate"] > 0.0
    assert full["distinct_rungs"] >= 2
    # Two-tenant filtered arm: per-tenant latency + hit rate, and the
    # cross-tenant isolation counter pinned at zero.
    tt = snap["arms"]["two_tenant_filtered"]
    assert tt["cross_tenant_cache_hits"] == 0
    for label in ("default", "b"):
        tenant = tt["tenants"][label]
        for key in ("p50_ms", "p95_ms", "cache_hit_rate", "submitted"):
            assert key in tenant, (label, key)
        assert tenant["cache_hit_rate"] > 0.0, (label, tenant)
