"""Index lifecycle store: on-disk format round-trips, mmap provenance,
out-of-core chunked build parity, and the sharded save/load path."""

import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import (
    IndexBuildConfig,
    Retriever,
    WarpSearchConfig,
    build_index,
    index_stats,
)
from repro.data import make_corpus, make_queries
from repro.store import (
    array_chunks,
    build_index_chunked,
    build_index_to_store,
    inspect_index,
    load_index,
    read_manifest,
    save_index,
)

ARRAY_FIELDS = (
    "centroids",
    "packed_codes",
    "token_doc_ids",
    "cluster_offsets",
    "cluster_sizes",
    "bucket_weights",
    "bucket_cutoffs",
)

BUILD_CFG = IndexBuildConfig(n_centroids=32, nbits=4, kmeans_iters=2)
SEARCH_CFG = WarpSearchConfig(nprobe=8, k=10, t_prime=400)


@pytest.fixture(scope="module")
def corpus():
    return make_corpus(n_docs=220, mean_doc_len=12, seed=17)


@pytest.fixture(scope="module")
def index(corpus):
    return build_index(
        corpus.emb, corpus.token_doc_ids, corpus.n_docs, BUILD_CFG
    )


def assert_indexes_bit_identical(a, b):
    for name in ARRAY_FIELDS:
        x, y = np.asarray(getattr(a, name)), np.asarray(getattr(b, name))
        assert x.dtype == y.dtype and x.shape == y.shape, name
        np.testing.assert_array_equal(x, y, err_msg=name)
    for name in ("dim", "nbits", "cap", "n_docs", "n_tokens"):
        assert getattr(a, name) == getattr(b, name), name


# ---- out-of-core chunked build parity -------------------------------------


@pytest.mark.parametrize("chunk_size", [97, 1024])
def test_chunked_build_bit_identical(corpus, index, chunk_size):
    """The streamed multi-pass build must reproduce the in-memory build
    exactly — same PRNG stream, same codec, same CSR layout."""
    chunked = build_index_chunked(
        array_chunks(corpus.emb, corpus.token_doc_ids, chunk_size),
        corpus.n_docs,
        BUILD_CFG,
    )
    assert_indexes_bit_identical(index, chunked)


def test_chunked_build_counts_tokens_itself(corpus, index):
    """n_tokens/dim discovery pass yields the same index."""
    chunked = build_index_chunked(
        array_chunks(corpus.emb, corpus.token_doc_ids, 333),
        corpus.n_docs,
        BUILD_CFG,
        n_tokens=None,
        dim=None,
    )
    assert_indexes_bit_identical(index, chunked)


def test_store_build_writes_mmap_backed_index(corpus, index, tmp_path):
    """build_index_to_store memmap-writes the O(N) arrays and the reload
    is bit-identical to the in-memory build."""
    out = str(tmp_path / "idx")
    stored = build_index_to_store(
        array_chunks(corpus.emb, corpus.token_doc_ids, 256),
        out, corpus.n_docs, BUILD_CFG,
        n_tokens=corpus.n_tokens, dim=128,
    )
    assert isinstance(stored.packed_codes, np.memmap)
    assert_indexes_bit_identical(index, stored)


@pytest.mark.slow_build
def test_out_of_core_build_large(tmp_path):
    """Larger corpus through small chunks — the tier-2 soak for the
    out-of-core path (deselected from tier-1; pass --slow-build)."""
    corpus = make_corpus(n_docs=2500, mean_doc_len=20, seed=5)
    cfg = IndexBuildConfig(n_centroids=128, nbits=4, kmeans_iters=3)
    ref = build_index(corpus.emb, corpus.token_doc_ids, corpus.n_docs, cfg)
    stored = build_index_to_store(
        array_chunks(corpus.emb, corpus.token_doc_ids, 2048),
        str(tmp_path / "big"), corpus.n_docs, cfg,
    )
    assert_indexes_bit_identical(ref, stored)


# ---- save -> load ---------------------------------------------------------


def test_save_load_mmap_provenance(index, tmp_path):
    """load_index must return memory-mapped views, not heap copies."""
    path = str(tmp_path / "idx")
    save_index(index, path, build_config=BUILD_CFG)
    loaded = load_index(path)
    for name in ARRAY_FIELDS:
        arr = getattr(loaded, name)
        assert isinstance(arr, np.memmap), f"{name} is {type(arr).__name__}"
        assert not arr.flags.writeable or arr.mode == "r"
    # mmap=False is the explicit copy path.
    copied = load_index(path, mmap=False)
    assert not isinstance(copied.packed_codes, np.memmap)
    assert_indexes_bit_identical(loaded, copied)


def test_save_load_stats_and_search_parity(corpus, index, tmp_path):
    path = str(tmp_path / "idx")
    save_index(index, path, build_config=BUILD_CFG)
    loaded = load_index(path)
    assert index_stats(loaded) == index_stats(index)

    q, qmask, _ = make_queries(corpus, n_queries=4, seed=18)
    plan_mem = Retriever.from_index(index).plan(SEARCH_CFG)
    plan_mmap = Retriever.from_store(path).plan(SEARCH_CFG)
    assert plan_mem.describe() == plan_mmap.describe()
    for i in range(4):
        a = plan_mem.retrieve(q[i], qmask[i])
        b = plan_mmap.retrieve(q[i], qmask[i])
        np.testing.assert_array_equal(np.asarray(a.doc_ids), np.asarray(b.doc_ids))
        np.testing.assert_array_equal(np.asarray(a.scores), np.asarray(b.scores))
    ab = plan_mem.retrieve_batch(q, qmask)
    bb = plan_mmap.retrieve_batch(q, qmask)
    np.testing.assert_array_equal(np.asarray(ab.doc_ids), np.asarray(bb.doc_ids))


def test_manifest_header_and_guards(index, tmp_path):
    path = str(tmp_path / "idx")
    save_index(index, path, build_config=BUILD_CFG)
    manifest = read_manifest(path)
    assert manifest["format"] == "warp-store"
    assert manifest["kind"] == "warp_index"
    assert manifest["build_config"]["nbits"] == BUILD_CFG.nbits
    for entry in manifest["arrays"].values():
        assert set(entry) >= {"file", "dtype", "shape"}
    # Refuses to clobber without overwrite=True.
    with pytest.raises(FileExistsError):
        save_index(index, path)
    save_index(index, path, overwrite=True)
    # Future format versions are rejected, not misread.
    import json

    manifest["version"] = 99
    with open(os.path.join(path, "MANIFEST.json"), "w") as f:
        json.dump(manifest, f)
    with pytest.raises(ValueError, match="version"):
        load_index(path)


def test_inspect_reports_measured_component_bytes(index, tmp_path):
    path = str(tmp_path / "idx")
    save_index(index, path)
    info = inspect_index(path)
    comp = info["components_bytes"]
    assert comp["packed_codes"] == index.n_tokens * (128 * 4 // 8)
    assert comp["doc_ids"] == index.n_tokens * 4
    assert comp["centroids"] == index.n_centroids * 128 * 4
    on_disk = sum(
        os.path.getsize(os.path.join(path, "arrays", f))
        for f in os.listdir(os.path.join(path, "arrays"))
    )
    assert info["total_bytes"] == on_disk


# ---- sharded path ---------------------------------------------------------

SHARDED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import sys, tempfile
import numpy as np
from repro.core import (IndexBuildConfig, WarpSearchConfig, Retriever,
                        build_sharded_index, sharded_search)
from repro.core.distributed import ShardedWarpIndex
from repro.core.types import WarpIndex
from repro.data import make_corpus, make_queries
from repro.store import load_index, save_index

corpus = make_corpus(n_docs=180, mean_doc_len=12, seed=2)
sidx = build_sharded_index(corpus.emb, corpus.token_doc_ids, corpus.n_docs, 2,
                           IndexBuildConfig(n_centroids=16, nbits=4, kmeans_iters=2))
path = tempfile.mkdtemp() + "/sidx"
save_index(sidx, path)
loaded = load_index(path)
assert isinstance(loaded, ShardedWarpIndex) and loaded.n_shards == 2
assert isinstance(loaded.packed_codes, np.memmap)
assert loaded.n_tokens_total == sidx.n_tokens_total

cfg = WarpSearchConfig(nprobe=8, k=10, t_prime=400)
q, qmask, _ = make_queries(corpus, n_queries=3, seed=3)
plan_a = Retriever.from_index(sidx).plan(cfg)
plan_b = Retriever.from_store(path).plan(cfg)
for i in range(3):
    a, b = plan_a.retrieve(q[i], qmask[i]), plan_b.retrieve(q[i], qmask[i])
    np.testing.assert_array_equal(np.asarray(a.doc_ids), np.asarray(b.doc_ids))
    np.testing.assert_array_equal(np.asarray(a.scores), np.asarray(b.scores))

# Per-shard directories reconstruct standalone WarpIndex views over the
# SAME binaries (byte offsets, no duplication).
for s in range(2):
    sh = load_index(os.path.join(path, f"shard_{s:05d}"))
    assert isinstance(sh, WarpIndex) and isinstance(sh.packed_codes, np.memmap)
    np.testing.assert_array_equal(np.asarray(sh.packed_codes),
                                  np.asarray(sidx.packed_codes)[s])
    np.testing.assert_array_equal(np.asarray(sh.token_doc_ids),
                                  np.asarray(sidx.token_doc_ids)[s])
print("OK")
"""


@pytest.mark.slow
def test_sharded_save_load_two_shard_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-c", SHARDED_SCRIPT],
        capture_output=True,
        text=True,
        env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=600,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout


def test_chunked_build_rejects_overstated_n_tokens(corpus):
    """An n_tokens larger than the stream must fail fast, not train
    k-means on uninitialized sample rows."""
    with pytest.raises(ValueError, match="yielded"):
        build_index_chunked(
            array_chunks(corpus.emb, corpus.token_doc_ids, 512),
            corpus.n_docs,
            BUILD_CFG,
            n_tokens=corpus.n_tokens + 100,
            dim=128,
        )


def test_interrupted_compact_swap_recovers(corpus, index, tmp_path):
    """Crash window between the two swap renames: the next load finishes
    the swap when the new base is complete, rolls back when it is not."""
    from repro.store import add_documents, recover_interrupted_compact
    from repro.store.format import COMPACT_OLD_SUFFIX, COMPACT_TMP_SUFFIX

    extra = make_corpus(n_docs=20, mean_doc_len=10, seed=99)

    # Case 1: complete .compact-tmp -> promoted.
    path = str(tmp_path / "idx1")
    save_index(index, path, build_config=BUILD_CFG)
    add_documents(path, extra.emb, extra.token_doc_ids, extra.n_docs)
    import shutil as _sh

    _sh.copytree(path, path + COMPACT_TMP_SUFFIX)  # stand-in "new base"
    os.rename(path, path + COMPACT_OLD_SUFFIX)  # crash mid-swap
    loaded = load_index(path)  # auto-recovers
    assert loaded.n_docs == index.n_docs + extra.n_docs
    assert not os.path.exists(path + COMPACT_TMP_SUFFIX)
    assert not os.path.exists(path + COMPACT_OLD_SUFFIX)

    # Case 2: tmp has no manifest (incomplete write) -> rolled back.
    path2 = str(tmp_path / "idx2")
    save_index(index, path2, build_config=BUILD_CFG)
    os.makedirs(path2 + COMPACT_TMP_SUFFIX)  # empty: manifest never landed
    os.rename(path2, path2 + COMPACT_OLD_SUFFIX)
    recover_interrupted_compact(path2)
    assert load_index(path2).n_docs == index.n_docs
    assert not os.path.exists(path2 + COMPACT_TMP_SUFFIX)


def test_add_documents_rejects_per_shard_view(tmp_path):
    """Per-shard views carry encode-only (zeroed) codec cutoffs; quantizing
    a delta against them must be refused, not silently corrupted."""
    from repro.core import build_sharded_index
    from repro.store import add_documents

    c = make_corpus(n_docs=60, mean_doc_len=8, seed=9)
    sidx = build_sharded_index(
        c.emb, c.token_doc_ids, c.n_docs, 2,
        IndexBuildConfig(n_centroids=8, nbits=4, kmeans_iters=1),
    )
    path = str(tmp_path / "sidx")
    save_index(sidx, path)
    extra = make_corpus(n_docs=10, mean_doc_len=8, seed=10)
    with pytest.raises(NotImplementedError, match="per-shard"):
        add_documents(
            os.path.join(path, "shard_00000"),
            extra.emb, extra.token_doc_ids, extra.n_docs,
        )
    with pytest.raises(NotImplementedError, match="single-device"):
        add_documents(path, extra.emb, extra.token_doc_ids, extra.n_docs)


def test_chunked_build_rejects_misaligned_doc_ids(corpus):
    """Alignment is validated even when n_tokens/dim are caller-supplied
    (the CLI path, which skips the counting pass)."""
    with pytest.raises(ValueError, match="align"):
        build_index_chunked(
            array_chunks(corpus.emb, corpus.token_doc_ids[:-5], 512),
            corpus.n_docs,
            BUILD_CFG,
            n_tokens=corpus.n_tokens,
            dim=128,
        )


def test_segment_dir_load_raises_clear_error(corpus, index, tmp_path):
    from repro.store import add_documents

    path = str(tmp_path / "idx")
    save_index(index, path)
    extra = make_corpus(n_docs=10, mean_doc_len=8, seed=3)
    seg_dir = add_documents(path, extra.emb, extra.token_doc_ids, extra.n_docs)
    with pytest.raises(ValueError, match="delta segment"):
        load_index(seg_dir)


def test_compact_lock_blocks_concurrent_writer(corpus, index, tmp_path):
    """A live lockfile rejects a second compact and shields the swap from
    reader-side recovery; a stale lock (dead pid) is taken over."""
    from repro.store import add_documents, compact
    from repro.store.format import compact_lock_path

    path = str(tmp_path / "idx")
    save_index(index, path)
    extra = make_corpus(n_docs=10, mean_doc_len=8, seed=3)
    add_documents(path, extra.emb, extra.token_doc_ids, extra.n_docs)

    lock = compact_lock_path(path)
    with open(lock, "w") as f:
        f.write(str(os.getpid()))  # "live writer" (this process)
    with pytest.raises(RuntimeError, match="already running"):
        compact(path)
    with open(lock, "w") as f:
        f.write("999999999")  # stale: no such pid
    compact(path)  # takes over the stale lock
    assert not os.path.exists(lock)
    assert load_index(path).n_docs == index.n_docs + extra.n_docs
