import pytest

# End-to-end behaviour tests for the paper's system live in:
#   test_engine.py      - WARP search parity + quality invariants
#   test_reduction.py   - two-stage reduction vs oracle (hypothesis)
#   test_quantization.py- residual codec
#   test_kernels.py     - Pallas kernels vs ref (shape/dtype sweeps)
#   test_distributed.py - doc-sharded shard_map engine
# This file keeps one cross-cutting smoke path alive.

import jax.numpy as jnp
import numpy as np

from repro.core import IndexBuildConfig, WarpSearchConfig, build_index, search
from repro.data import make_corpus, make_queries


def test_end_to_end_smoke():
    corpus = make_corpus(n_docs=120, mean_doc_len=12, seed=42)
    idx = build_index(
        corpus.emb, corpus.token_doc_ids, corpus.n_docs,
        IndexBuildConfig(n_centroids=32, nbits=4, kmeans_iters=2),
    )
    q, qmask, rel = make_queries(corpus, n_queries=2, seed=7)
    res = search(idx, q[0], jnp.asarray(qmask[0]), WarpSearchConfig(nprobe=8, k=5))
    assert res.scores.shape == (5,)
    assert res.doc_ids.shape == (5,)
    assert np.isfinite(np.asarray(res.scores)).any()
