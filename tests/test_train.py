"""Training substrate: optimizer, loop, checkpointing, fault tolerance,
gradient compression."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import (
    AdamWConfig,
    TrainState,
    adamw_init,
    adamw_update,
    latest_step,
    make_train_step,
    restore_checkpoint,
    save_checkpoint,
    train_loop,
)
from repro.train.compression import compress_grads, init_error_state, quantize_int8, dequantize_int8
from repro.train.loop import FailureInjector


def _quadratic_loss(params, batch):
    """Simple convex problem: fit w to targets."""
    pred = batch["x"] @ params["w"] + params["b"]
    loss = jnp.mean((pred - batch["y"]) ** 2)
    return loss, {"mse": loss}


def _make_problem(seed=0, n=256, d=8):
    rng = np.random.default_rng(seed)
    w_true = rng.standard_normal((d, 1)).astype(np.float32)
    x = rng.standard_normal((n, d)).astype(np.float32)
    y = x @ w_true + 0.01 * rng.standard_normal((n, 1)).astype(np.float32)
    params = {"w": jnp.zeros((d, 1)), "b": jnp.zeros((1,))}
    batch = {"x": jnp.asarray(x), "y": jnp.asarray(y)}
    return params, batch


def test_adamw_descends():
    params, batch = _make_problem()
    opt = adamw_init(params)
    cfg = AdamWConfig(lr=0.05, weight_decay=0.0, warmup_steps=0, total_steps=200)
    losses = []
    for _ in range(100):
        (loss, _), grads = jax.value_and_grad(_quadratic_loss, has_aux=True)(params, batch)
        params, opt, _ = adamw_update(cfg, params, grads, opt)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.05


def test_microbatch_equals_fullbatch():
    params, batch = _make_problem()
    cfg = AdamWConfig(lr=0.01, weight_decay=0.0, warmup_steps=0)
    s1 = TrainState.create(params)
    s2 = TrainState.create(params)
    step1 = jax.jit(make_train_step(_quadratic_loss, cfg, microbatches=1))
    step4 = jax.jit(make_train_step(_quadratic_loss, cfg, microbatches=4))
    s1, m1 = step1(s1, batch)
    s2, m2 = step4(s2, batch)
    # Averaged-gradient parity (loss metric is mean over microbatches).
    np.testing.assert_allclose(
        np.asarray(s1.params["w"]), np.asarray(s2.params["w"]), rtol=1e-4, atol=1e-5
    )


def test_int8_roundtrip_bounded_error():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(1000).astype(np.float32))
    q, scale = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, scale) - x))
    assert err.max() <= float(scale) * 0.5 + 1e-6


def test_compression_error_feedback_converges():
    """With error feedback, compressed training still reaches low loss."""
    params, batch = _make_problem()
    cfg = AdamWConfig(lr=0.05, weight_decay=0.0, warmup_steps=0)
    step = jax.jit(make_train_step(_quadratic_loss, cfg, compression=True))
    state = TrainState.create(params, compression=True)
    losses = []
    for _ in range(150):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.05


def test_checkpoint_roundtrip(tmp_path):
    params, _ = _make_problem()
    state = TrainState.create(params)
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 7, state)
    assert latest_step(d) == 7
    restored, step = restore_checkpoint(d, state)
    assert step == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_ignores_uncommitted(tmp_path):
    params, _ = _make_problem()
    state = TrainState.create(params)
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 5, state)
    # Fake a torn write: directory without commit marker.
    os.makedirs(os.path.join(d, "step_00000009"))
    assert latest_step(d) == 5


def test_train_loop_resumes_after_injected_failure(tmp_path):
    """Kill at step 30, restart, verify it resumes from the checkpoint and
    finishes with the same final state as an uninterrupted run."""
    params, batch = _make_problem()
    d = str(tmp_path / "ckpt")
    cfg = AdamWConfig(lr=0.02, weight_decay=0.0, warmup_steps=0)

    kwargs = dict(
        init_params_fn=lambda: params,
        loss_fn=_quadratic_loss,
        batch_iter=lambda step: batch,
        opt_cfg=cfg,
        n_steps=50,
        ckpt_every=10,
        log_every=1000,
        log_fn=lambda s: None,
    )

    with pytest.raises(RuntimeError, match="injected failure"):
        train_loop(ckpt_dir=d, failure=FailureInjector(fail_at=(30,)), **kwargs)
    assert latest_step(d) == 30

    state_resumed, _ = train_loop(ckpt_dir=d, **kwargs)

    state_clean, _ = train_loop(ckpt_dir=str(tmp_path / "clean"), **kwargs)
    for a, b in zip(jax.tree.leaves(state_resumed.params), jax.tree.leaves(state_clean.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)


def test_elastic_restore_onto_new_sharding(tmp_path):
    """Checkpoint is mesh-agnostic: restore with explicit shardings works."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    params, _ = _make_problem()
    state = TrainState.create(params)
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 1, state)
    mesh = jax.make_mesh((1,), ("data",))
    shardings = jax.tree.map(lambda _: NamedSharding(mesh, P()), state)
    restored, _ = restore_checkpoint(d, state, shardings=shardings)
    np.testing.assert_array_equal(
        np.asarray(restored.params["w"]), np.asarray(state.params["w"])
    )
